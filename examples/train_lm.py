"""End-to-end training driver example (deliverable b).

Trains a qwen2-family model on the synthetic pipeline with the full
substrate stack (AdamW + cosine, clipping, async checkpointing, preemption
handling) and verifies the loss decreases. Defaults are sized for this
1-core CPU container (~20M params, 120 steps); pass --full for the ~100M
variant used on real hardware.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.models.zoo import ModelBundle
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param variant (slow on 1 CPU core)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("qwen2-1.5b")
    if args.full:
        cfg = dataclasses.replace(base, layers=8, d_model=512, heads=8,
                                  kv_heads=2, d_ff=2048, vocab=32000,
                                  arch_id="qwen2-100m")
    else:
        cfg = dataclasses.replace(base, layers=4, d_model=256, heads=4,
                                  kv_heads=2, d_ff=1024, vocab=8192,
                                  arch_id="qwen2-20m")
    bundle = ModelBundle(cfg)
    print(f"model: {cfg.arch_id} ({bundle.param_count()/1e6:.1f}M params), "
          f"{args.steps} steps of {args.batch}x{args.seq}")

    params = bundle.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    lr = cosine_schedule(args.lr, warmup=args.steps // 10, total=args.steps)
    loss_fn = bundle.loss_fn(None)

    @jax.jit
    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, opt, params, lr=lr)
        return params, opt, loss, gnorm

    ds = SyntheticLMDataset(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                       global_batch=args.batch, seed=0))
    ckpt = CheckpointManager(args.ckpt_dir, keep_last=2)
    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in ds.global_batch_at(step).items()}
        params, opt, loss, gnorm = step_fn(params, opt, batch)
        losses.append(float(loss))
        if step % 10 == 0:
            tok_s = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(gnorm):.2f} ({tok_s:.0f} tok/s)", flush=True)
        if (step + 1) % 50 == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt})
    ckpt.save(args.steps, {"params": params, "opt": opt}, blocking=True)

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss: {first:.4f} -> {last:.4f} "
          f"({(first - last) / first * 100:.1f}% reduction)")
    if last >= first:
        print("ERROR: loss did not decrease", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
