"""Batched serving example: prefill + decode with continuous batching,
with a co-simulation twist — every served wave is ALSO fed to the
simulation plane, reporting what the same batch would cost on a modeled
systolic accelerator (latency/energy per token).

    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-1.5b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Simulator
from repro.configs import get_config
from repro.models.zoo import ModelBundle


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--sim-array", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    bundle = ModelBundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B = args.batch
    max_len = args.prompt_len + args.gen_len

    prefill = jax.jit(bundle.prefill_step(None))
    decode = jax.jit(bundle.decode_step(None), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(
        1, min(cfg.vocab, 512), size=(B, args.prompt_len), dtype=np.int32))}
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((B, args.prompt_len, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model),
                                     jnp.bfloat16)

    t0 = time.time()
    logits, _ = prefill(params, batch)
    cache = bundle.init_cache(batch=B, cache_len=max_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    outs = [tok]
    for i in range(args.gen_len - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs.append(tok)
    wall = time.time() - t0
    gen = np.asarray(jnp.concatenate(outs, 1))
    print(f"served {B} seqs x {args.gen_len} tokens in {wall:.2f}s "
          f"({B * args.gen_len / wall:.1f} tok/s on CPU)")
    print("sample:", gen[0, :10].tolist())

    # co-simulation: cost of the same wave on modeled silicon
    full_cfg = get_config(args.arch)          # full-size arch for the model
    sim = Simulator.from_preset("tpu-like", array=args.sim_array)
    rp = sim.run_lm(full_cfg, seq=args.prompt_len, batch=B, mode="prefill")
    rd = sim.run_lm(full_cfg, seq=args.prompt_len, batch=B, mode="decode",
                    cache_len=max_len)
    tot_cyc, tot_e = sim.wave_cost(rp, rd, args.gen_len)
    print(f"\nsimulated on {args.sim_array}x{args.sim_array} WS @1GHz "
          f"({full_cfg.arch_id} full size):")
    print(f"  prefill {rp.total_cycles:.3e} cyc; decode "
          f"{rd.total_cycles:.3e} cyc/step")
    print(f"  wave total: {tot_cyc/1e6:.1f} Mcycles = {tot_cyc/1e9*1000:.1f} ms, "
          f"{tot_e*1e-9:.1f} mJ, "
          f"{tot_e*1e-12/(B*args.gen_len)*1000:.3f} mJ/token")


if __name__ == "__main__":
    main()
